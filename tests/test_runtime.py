"""Continuous-batching serving runtime: pool correctness, token parity with
the sequential Engine, ForkSession admission mid-stream, the FaaS front-end
service classes, and the scheduler's measured mode."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api as tidal
from repro.core.scheduler import (ClusterSim, FunctionProfile,
                                  SchedulerConfig, make_trace, summarize)
from repro.core.streaming import ForkSession, StreamEntry, WeightStreamer
from repro.core.template_server import TemplateServer
from repro.models.registry import get_smoke_model
from repro.runtime.continuous import ContinuousBatchingEngine
from repro.runtime.engine import Engine
from repro.runtime.faas import FaaSRuntime, measure_service_times
from repro.runtime.kv_pool import KVCachePool
from repro.utils import path_str

MAX_LEN = 24


def _mixed_requests(vocab, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, s).astype(np.int32), n)
            for s, n in [(4, 5), (9, 3), (6, 7), (11, 4), (5, 6)]]


def _sequential_tokens(m, params, reqs):
    eng = Engine(m, params, donate_cache=False)
    return [eng.generate(p[None], max_new_tokens=n,
                         cache_len=MAX_LEN).tokens[0] for p, n in reqs]


# ---------------------------------------------------------------------------
# KVCachePool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-v3-671b",
                                  "zamba2-2.7b"])
def test_kv_pool_scatter_gather_roundtrip(arch):
    m = get_smoke_model(arch)
    pool = KVCachePool(m, n_slots=3, max_len=8)
    subs = []
    for slot in range(3):
        sub = jax.tree.map(
            lambda t: jnp.full(t.shape, slot + 1, t.dtype),
            m.make_cache(1, 8))
        subs.append(sub)
        pool.write_slot(slot, sub)
    for slot in (2, 0, 1):
        got = pool.read_slot(slot)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(subs[slot])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kv_pool_slot_accounting():
    m = get_smoke_model("smollm-135m", n_layers=1)
    pool = KVCachePool(m, n_slots=2, max_len=4)
    a, b = pool.alloc(), pool.alloc()
    assert pool.n_free == 0
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.release(a)
    assert pool.n_free == 1
    with pytest.raises(ValueError):
        pool.release(a)                      # double free
    assert pool.alloc() == a


# ---------------------------------------------------------------------------
# ContinuousBatchingEngine vs sequential Engine
# ---------------------------------------------------------------------------

def test_continuous_matches_sequential_mixed_lengths():
    """Bit-identical greedy tokens for a mixed-length request set, with
    fewer slots than requests (slot reuse + mid-decode admission)."""
    m = get_smoke_model("smollm-135m", n_layers=2)
    params = m.init_params(jax.random.PRNGKey(0))
    reqs = _mixed_requests(m.cfg.vocab_size)
    want = _sequential_tokens(m, params, reqs)

    cbe = ContinuousBatchingEngine(m, params, n_slots=2, max_len=MAX_LEN)
    rids = [cbe.submit(p, n) for p, n in reqs]
    out = cbe.run()
    for rid, (p, n), w in zip(rids, reqs, want):
        assert out[rid].n_generated == n
        assert out[rid].prompt_len == len(p)
        np.testing.assert_array_equal(out[rid].tokens, w)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "zamba2-2.7b",
                                  "xlstm-1.3b"])
def test_continuous_matches_sequential_other_families(arch):
    m = get_smoke_model(arch)
    params = m.init_params(jax.random.PRNGKey(0))
    reqs = _mixed_requests(m.cfg.vocab_size, seed=1)[:3]
    want = _sequential_tokens(m, params, reqs)
    cbe = ContinuousBatchingEngine(m, params, n_slots=2, max_len=MAX_LEN)
    rids = [cbe.submit(p, n) for p, n in reqs]
    out = cbe.run()
    for rid, w in zip(rids, want):
        np.testing.assert_array_equal(out[rid].tokens, w)


def test_continuous_rejects_oversized_and_encdec():
    m = get_smoke_model("smollm-135m", n_layers=1)
    cbe = ContinuousBatchingEngine(m, m.init_params(jax.random.PRNGKey(0)),
                                   n_slots=1, max_len=8)
    with pytest.raises(ValueError):
        cbe.submit(np.zeros(6, np.int32), max_new_tokens=4)   # 6+4 > 8
    enc = get_smoke_model("whisper-medium")
    with pytest.raises(NotImplementedError):
        ContinuousBatchingEngine(enc, None)


def _slow_fork_session(m, params, delay_s=0.003):
    """A ForkSession whose weights stream with an artificial per-leaf delay,
    so admission reliably happens while later layers are still in flight."""
    flat = {path_str(p): np.asarray(l)
            for p, l in jax.tree_util.tree_leaves_with_path(params)}

    def fetch(arr):
        time.sleep(delay_s)
        return arr

    entries = [StreamEntry((path, ()), fetch=lambda a=arr: fetch(a))
               for path, arr in flat.items()]
    streamer = WeightStreamer(entries, {}, {}).start()
    return ForkSession(m, streamer, {path: ("whole",) for path in flat})


def test_admission_from_fork_session_mid_stream():
    """A request admitted while the session's weights are still streaming
    (layer-streamed prefill) must yield the same tokens as plain params —
    and the rest of the mixed batch must stay bit-identical too."""
    m = get_smoke_model("smollm-135m", n_layers=3)
    params = m.init_params(jax.random.PRNGKey(0))
    reqs = _mixed_requests(m.cfg.vocab_size, seed=7)
    want = _sequential_tokens(m, params, reqs)

    session = _slow_fork_session(m, params)
    cbe = ContinuousBatchingEngine(m, session, n_slots=2, max_len=MAX_LEN)
    rids = [cbe.submit(p, n) for p, n in reqs]
    out = cbe.run()
    # first admission happened while the stream was in flight
    assert out[rids[0]].streamed_prefill
    for rid, w in zip(rids, want):
        np.testing.assert_array_equal(out[rid].tokens, w)


def test_forked_session_from_template_server_parity():
    """End-to-end: TemplateServer.fork -> continuous batching == Engine."""
    m = get_smoke_model("smollm-135m", n_layers=3)
    params = m.init_params(jax.random.PRNGKey(0))
    srv = TemplateServer(trace_batch=1, trace_seq=8)
    srv.register(tidal.static_function("f", m, params), {})
    session, _ = srv.fork("f", {})
    reqs = _mixed_requests(m.cfg.vocab_size, seed=11)[:3]
    want = _sequential_tokens(m, params, reqs)
    cbe = ContinuousBatchingEngine(m, session, n_slots=2, max_len=MAX_LEN)
    rids = [cbe.submit(p, n) for p, n in reqs]
    out = cbe.run()
    for rid, w in zip(rids, want):
        np.testing.assert_array_equal(out[rid].tokens, w)


# ---------------------------------------------------------------------------
# FaaSRuntime + measured-mode scheduler
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def faas_runtime():
    m = get_smoke_model("smollm-135m", n_layers=2)
    rt = FaaSRuntime(n_slots=2, max_len=MAX_LEN, trace_seq=8)
    params = m.init_params(jax.random.PRNGKey(0))
    rt.deploy(tidal.static_function("fn-static", m, params), {},
              prewarm_seq=8)
    rt.deploy(tidal.lora_function("fn-lora", m, params,
                                  ["blocks.attn.wq"], n_adapters=2),
              {"adapter": "adapter-0"}, prewarm_seq=8)
    return m, params, rt


def test_faas_service_classes_and_parity(faas_runtime):
    m, params, rt = faas_runtime
    prompt = np.arange(10, dtype=np.int32) % m.cfg.vocab_size
    want = Engine(m, params, donate_cache=False).generate(
        prompt[None], max_new_tokens=4, cache_len=MAX_LEN).tokens[0]

    r1 = rt.submit("fn-static", {}, prompt, 4)      # first invocation
    r2 = rt.submit("fn-static", {}, prompt, 4)      # engine kept alive
    rt.evict("fn-static")                           # keep-alive expiry
    r3 = rt.submit("fn-static", {}, prompt, 4)      # re-fork
    assert (r1.kind, r2.kind, r3.kind) == ("cold", "warm", "fork")
    assert r1.fork_stats is not None and r2.fork_stats is None
    for r in (r1, r2, r3):
        np.testing.assert_array_equal(r.tokens, want)

    with pytest.raises(KeyError):
        rt.submit("nope", {}, prompt, 4)


def test_faas_submit_many_shares_one_engine(faas_runtime):
    """submit_many enqueues every request before any engine drains: same-
    (fn, event) requests share one continuous-batching engine and decode
    together, and each output stays bit-identical to a sequential run."""
    m, params, rt = faas_runtime
    rt.evict()
    reqs = _mixed_requests(m.cfg.vocab_size, seed=5)[:3]
    want = _sequential_tokens(m, params, reqs)
    results = rt.submit_many([("fn-static", {}, p, n) for p, n in reqs])
    # one fork, then the batch-mates found the same engine already warm
    assert results[0].kind in ("cold", "fork")
    assert [r.kind for r in results[1:]] == ["warm", "warm"]
    assert len([k for k in rt.warm_engines() if k[0] == "fn-static"]) == 1
    for r, w in zip(results, want):
        np.testing.assert_array_equal(r.tokens, w)


def test_faas_submit_many_validates_before_enqueue(faas_runtime):
    """A bad batch member fails the whole call BEFORE anything is enqueued
    or forked: no orphaned requests, no misclassified invocations, and
    collected results don't accumulate on warm engines."""
    m, params, rt = faas_runtime
    good = np.arange(6, dtype=np.int32)
    too_long = np.arange(MAX_LEN, dtype=np.int32)
    with pytest.raises(ValueError, match="exceeds runtime max_len"):
        rt.submit_many([("fn-static", {}, good, 4),
                        ("fn-static", {}, too_long, 4)])
    with pytest.raises(KeyError):
        rt.submit_many([("fn-static", {}, good, 4),
                        ("not-deployed", {}, good, 4)])
    r = rt.submit("fn-static", {}, good, 4)
    assert r.tokens.shape == (4,)
    for key in rt.warm_engines():
        eng = rt._engines[key].engine
        assert eng.n_pending == 0          # nothing orphaned in queues
        assert not eng.results             # collected results are popped


def test_faas_ttft_includes_fork_time(faas_runtime):
    """Fork/cold TTFT must cover the synchronous fork, not just
    prefill+decode — that is the number Eq. 1 and measured mode consume."""
    m, params, rt = faas_runtime
    prompt = np.arange(6, dtype=np.int32)
    rt.evict("fn-static")
    forked = rt.submit("fn-static", {}, prompt, 2)
    warm = rt.submit("fn-static", {}, prompt, 2)
    assert forked.kind == "fork" and warm.kind == "warm"
    assert forked.fork_stats.fork_s > 0
    assert forked.ttft_s > forked.fork_stats.fork_s


def test_faas_deploy_prewarms_engine_entry_points(faas_runtime):
    """deploy() pre-compiles the engine's serve entry points (shared per
    model), so the executable cache holds exactly one prefill + one decode
    signature for the shared smoke model."""
    m, params, rt = faas_runtime
    kinds = {k[1] for k in rt.exe_cache.keys()}
    assert kinds == {"prefill", "decode-pool"}
    assert rt.exe_cache.stats.misses == 2          # dedup'd across functions
    assert rt.exe_cache.stats.hits >= 1            # 2nd deploy hit the cache


def test_faas_lora_adapters_get_separate_engines(faas_runtime):
    m, params, rt = faas_runtime
    prompt = np.arange(8, dtype=np.int32) % m.cfg.vocab_size
    a0 = rt.submit("fn-lora", {"adapter": "adapter-0"}, prompt, 4)
    a1 = rt.submit("fn-lora", {"adapter": "adapter-1"}, prompt, 4)
    again = rt.submit("fn-lora", {"adapter": "adapter-1"}, prompt, 4)
    assert a1.kind in ("cold", "fork") and again.kind == "warm"
    np.testing.assert_array_equal(a1.tokens, again.tokens)
    # different adapters are different dynamic weights -> usually different
    # engines; both decode greedily from the same base so shapes agree
    assert a0.tokens.shape == a1.tokens.shape


def test_cluster_sim_measured_mode():
    """ClusterSim in measured mode: warm/fork/cold service times come from
    the live runtime's wall clock, not the analytic oracle."""
    from repro.core.plans import plan_for

    m = get_smoke_model("smollm-135m", n_layers=1)
    rt = FaaSRuntime(n_slots=2, max_len=MAX_LEN, trace_seq=8)
    params = m.init_params(jax.random.PRNGKey(1))
    rt.deploy(tidal.lora_function("fn-live", m, params,
                                  ["blocks.attn.wq"], n_adapters=2),
              {"adapter": "adapter-0"}, prewarm_seq=8)
    mst = measure_service_times(rt, {"fn-live": {"adapter": "adapter-1"}},
                                prompt_len=8, max_new_tokens=2)
    for kind in ("warm", "fork", "cold"):
        assert mst.service_s("fn-live", kind) is not None
    assert mst.service_s("fn-live", "warm") < mst.service_s("fn-live", "fork")

    plan = plan_for("smollm-135m", 1, 867)
    fns = {"fn-live": FunctionProfile(
        name="fn-live",
        plan_for_len=lambda L: plan_for("smollm-135m", 1, L),
        dynamic_bytes=1 << 20, model_bytes=plan.total_weight_bytes)}
    trace = make_trace({"fn-live": 2.0}, duration_s=10.0,
                       fn_tasks={"fn-live": "mail"}, seed=0)
    cfg = SchedulerConfig(n_gpus=2, policy="tidal", dk=True, keep_alive_s=5.0,
                          measured=mst)
    results = ClusterSim(cfg, fns).run(trace)
    assert results
    for r in results:
        if not r.rejected:
            assert r.service_s == pytest.approx(
                mst.service_s("fn-live", r.kind))
    s = summarize(results)
    assert s["warm"] + s["fork"] + s["cold"] == s["n"] - s["rejected"]


def test_cluster_sim_measured_falls_back_to_analytic():
    """Functions absent from the measured table use the analytic oracle."""
    from repro.core.plans import plan_for

    class Empty:
        def service_s(self, fn, kind, input_len=None):
            return None

    plan = plan_for("smollm-135m", 1, 867)
    fns = {"f": FunctionProfile(
        name="f", plan_for_len=lambda L: plan_for("smollm-135m", 1, L),
        model_bytes=plan.total_weight_bytes)}
    trace = make_trace({"f": 1.0}, duration_s=5.0, fn_tasks={"f": "mail"},
                       seed=1)
    base = ClusterSim(SchedulerConfig(n_gpus=1), fns).run(trace)
    meas = ClusterSim(SchedulerConfig(n_gpus=1, measured=Empty()),
                      fns).run(trace)
    assert [r.service_s for r in base] == [r.service_s for r in meas]
