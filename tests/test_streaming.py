"""Template server + adaptive forking + overlapped streaming (TIDAL §5.2)."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api as tidal
from repro.core.forking import DonationGuard, copy_for_write, safe_jit
from repro.core.streaming import (ForkSession, StreamEntry, WeightStreamer,
                                  streamed_prefill)
from repro.core.template_server import TemplateServer
from repro.data.pipeline import make_prompts
from repro.models.registry import get_smoke_model
from repro.utils import path_str


@pytest.fixture(scope="module")
def smoke_setup():
    m = get_smoke_model("smollm-135m", n_layers=6)
    params = m.init_params(jax.random.PRNGKey(0))
    srv = TemplateServer(trace_batch=2, trace_seq=16)
    fn = tidal.static_function("smol", m, params)
    srv.register(fn, {})
    return m, params, srv


def test_streamed_prefill_exact(smoke_setup):
    """Layer-streamed execution with async weight arrival must equal the
    monolithic prefill bit-for-bit (sync-event correctness)."""
    m, params, srv = smoke_setup
    sess, stats = srv.fork("smol", {})
    toks = jnp.asarray(make_prompts(m.cfg.vocab_size, 2, 16))
    lg_s, cache_s = streamed_prefill(sess, {"tokens": toks}, m.make_cache(2, 16))
    lg_r, cache_r = m.prefill(params, {"tokens": toks}, m.make_cache(2, 16))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_r))
    for a, b in zip(jax.tree.leaves(cache_s), jax.tree.leaves(cache_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["smollm-135m", "phi3.5-moe-42b-a6.6b",
                                  "deepseek-v3-671b"])
def test_streamed_prefill_offset_per_family(arch):
    """streamed_prefill(offset=) — the suffix path chunked prefill and
    prefix reuse ride while weights are in flight — must equal both
    ``prefill_from`` and a monolithic full prefill bit-for-bit on every
    attention family, INCLUDING MLA's latent cache (positions, RoPE and
    mask all carry the offset)."""
    m = get_smoke_model(arch, n_layers=2)
    params = m.init_params(jax.random.PRNGKey(0))
    srv = TemplateServer(trace_batch=1, trace_seq=16)
    srv.register(tidal.static_function("f", m, params), {})
    sess, _ = srv.fork("f", {})
    toks = jnp.asarray(make_prompts(m.cfg.vocab_size, 1, 16))
    lg_r, cache_r = m.prefill(params, {"tokens": toks}, m.make_cache(1, 16))
    _, cache_p = m.prefill(params, {"tokens": toks[:, :8]},
                           m.make_cache(1, 16))
    lg_s, cache_s = streamed_prefill(sess, {"tokens": toks[:, 8:]},
                                     cache_p, offset=8)
    lg_f, cache_f = m.prefill_from(params, {"tokens": toks[:, 8:]},
                                   cache_p, jnp.int32(8))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_f))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_r))
    for a, b in zip(jax.tree.leaves(cache_s), jax.tree.leaves(cache_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-2.7b"])
def test_streamed_prefill_hybrid_per_family(arch):
    """Recurrent hybrids stream block-by-block in scan execution order
    (mLSTM/sLSTM units; mamba units + the shared attention block) and
    must equal the monolithic prefill bit-for-bit — logits AND every
    recurrent-state / KV cache leaf."""
    m = get_smoke_model(arch, n_layers=4)
    params = m.init_params(jax.random.PRNGKey(0))
    srv = TemplateServer(trace_batch=2, trace_seq=16)
    srv.register(tidal.static_function("f", m, params), {})
    sess, _ = srv.fork("f", {})
    toks = jnp.asarray(make_prompts(m.cfg.vocab_size, 2, 16))
    lg_s, cache_s = streamed_prefill(sess, {"tokens": toks},
                                     m.make_cache(2, 16))
    lg_r, cache_r = m.prefill(params, {"tokens": toks}, m.make_cache(2, 16))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_r))
    ls, lr = jax.tree.leaves(cache_s), jax.tree.leaves(cache_r)
    assert len(ls) == len(lr)
    for a, b in zip(ls, lr):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # recurrent state is not position-addressable: no suffix streaming
    with pytest.raises(ValueError):
        streamed_prefill(sess, {"tokens": toks[:, 8:]},
                         m.make_cache(2, 16), offset=8)


def test_streaming_follows_traced_order(smoke_setup):
    m, params, srv = smoke_setup
    sess, _ = srv.fork("smol", {})
    sess.streamer.wait_all()
    done = sess.streamer.completed_order
    tmpl = srv.templates["smol"]
    expect = [k for k in tmpl.static_order
              if k[0] not in sess.streamer.resident]
    assert done == expect


def test_fork_reuses_resident_buffers(smoke_setup):
    m, params, srv = smoke_setup
    srv.set_resident_bytes("smol", srv.templates["smol"].total_bytes)
    s1, st1 = srv.fork("smol", {})
    s2, st2 = srv.fork("smol", {})
    assert st1.reused_bytes > 0 and st1.streamed_bytes == 0
    # the SAME device buffer is shared across forks (template sharing)
    a1 = s1.leaf("embed")
    a2 = s2.leaf("embed")
    assert a1 is a2
    srv.set_resident_bytes("smol", 0)


def test_cow_template_unmodified_after_invocations(smoke_setup):
    """Copy-on-write: invocations must never mutate template buffers."""
    m, params, srv = smoke_setup
    srv.set_resident_bytes("smol", srv.templates["smol"].total_bytes)
    sess, _ = srv.fork("smol", {})
    guard = DonationGuard.guard(dict(srv.device_cache["smol"]))
    p = sess.params()
    toks = jnp.asarray(make_prompts(m.cfg.vocab_size, 2, 16))
    lg, cache = m.prefill(p, {"tokens": toks}, m.make_cache(2, 32))
    for pos in range(16, 20):
        lg, cache = m.decode_step(p, cache, {"tokens": jnp.zeros((2, 1), jnp.int32)}, pos)
    assert guard.check(dict(srv.device_cache["smol"])) == []
    srv.set_resident_bytes("smol", 0)


def test_safe_jit_refuses_donating_guarded_args():
    with pytest.raises(ValueError):
        safe_jit(lambda p, x: p, guarded_argnums=(0,), donate_argnums=(0,))
    fn = safe_jit(lambda p, x: p + x, guarded_argnums=(0,), donate_argnums=(1,))
    out = fn(jnp.ones(4), jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones(4))


def test_copy_for_write_is_private():
    a = jnp.arange(8.0)
    b = copy_for_write(a)
    assert a is not b
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lora_dynamic_detection_and_fork():
    m = get_smoke_model("smollm-135m", n_layers=4)
    params = m.init_params(jax.random.PRNGKey(0))
    srv = TemplateServer(trace_batch=1, trace_seq=16)
    fn = tidal.lora_function("lor", m, params, ["blocks.attn.wq"], n_adapters=3)
    tmpl = srv.register(fn, {"adapter": "adapter-0"})
    assert tmpl.dynamic == set()                    # one observation: unknown
    s1, st1 = srv.fork("lor", {"adapter": "adapter-1"})
    assert st1.new_dynamic == ("blocks.attn.wq",)   # detected on diff
    s2, st2 = srv.fork("lor", {"adapter": "adapter-2"})
    assert st2.new_dynamic == ()                    # incremental: already out
    assert st2.dynamic_bytes > 0
    # dynamic weight differs across requests; static identical
    p1, p2 = s1.params(), s2.params()
    assert float(jnp.max(jnp.abs(
        p1["blocks"]["attn"]["wq"] - p2["blocks"]["attn"]["wq"]))) > 0
    np.testing.assert_array_equal(np.asarray(p1["embed"]),
                                  np.asarray(p2["embed"]))
    # dynamic fraction is small (the paper's <1% premise at full scale)
    assert st2.dynamic_bytes < 0.35 * tmpl.total_bytes


def test_lora_merge_correctness():
    """apply_lora must equal base + A@B numerically."""
    m = get_smoke_model("smollm-135m", n_layers=2)
    params = m.init_params(jax.random.PRNGKey(0))
    base = tidal.checkpoint_of("b", params)
    adapter = tidal.lora_checkpoint("a", m, ["final_norm"], rank=2, seed=7)
    w = tidal.apply_lora(tidal.load(base), m, adapter, alpha=2.0)
    got = w["final_norm"].materialize()
    A = adapter.arrays["final_norm.A"]
    B = adapter.arrays["final_norm.B"]
    want = (np.asarray(params["final_norm"])
            + (A @ B).reshape(-1).astype(np.float32) * 2.0)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_streamer_failure_surfaces_everywhere_no_hang():
    """A fetch that raises must surface the error on every blocked get()
    and on wait_all() — consumers must never hang.  Slices that landed
    before the failure stay servable."""
    def ok():
        return np.ones(4, np.float32)

    def boom():
        time.sleep(0.02)
        raise RuntimeError("host pool gone")

    ws = WeightStreamer([StreamEntry(("a", ()), fetch=ok),
                         StreamEntry(("b", ()), fetch=boom),
                         StreamEntry(("c", ()), fetch=ok)], {}, {})

    # a consumer already blocked on a post-failure key before start()
    got = {}

    def consumer():
        try:
            got["c"] = ws.get(("c", ()))
        except BaseException as e:           # noqa: BLE001 — assert below
            got["c"] = e

    t = threading.Thread(target=consumer)
    t.start()
    ws.start()
    t.join(timeout=10.0)
    assert not t.is_alive(), "blocked consumer hung after stream failure"
    assert isinstance(got["c"], RuntimeError)

    np.testing.assert_array_equal(
        np.asarray(ws.get(("a", ()))), np.ones(4))   # completed before boom
    with pytest.raises(RuntimeError, match="host pool gone"):
        ws.get(("b", ()))
    with pytest.raises(RuntimeError, match="host pool gone"):
        ws.wait_all()


def test_fork_session_params_surfaces_stream_error():
    """ForkSession.params() gathers every leaf — a failed transfer must
    propagate out of it, not deadlock the invocation."""
    m = get_smoke_model("smollm-135m", n_layers=1)
    params = m.init_params(jax.random.PRNGKey(0))
    flat = {path_str(p): np.asarray(l)
            for p, l in jax.tree_util.tree_leaves_with_path(params)}

    entries = []
    for i, (path, arr) in enumerate(sorted(flat.items())):
        if i == 1:
            def bad():
                raise IOError("checkpoint shard unreachable")
            entries.append(StreamEntry((path, ()), fetch=bad))
        else:
            entries.append(StreamEntry((path, ()), fetch=lambda a=arr: a))
    session = ForkSession(m, WeightStreamer(entries, {}, {}).start(),
                          {path: ("whole",) for path in flat})
    with pytest.raises(IOError, match="shard unreachable"):
        session.params()


def test_eq1_feedback_loop(smoke_setup):
    """observe_ttft drives residency: tiny TTFT -> resident prefix appears."""
    m, params, srv = smoke_setup
    srv.observe_ttft("smol", 1e-6)
    assert len(srv.device_cache["smol"]) > 0
    srv.observe_ttft("smol", 100.0)
    # EWMA: still adapting downwards takes observations; force directly
    srv.set_resident_bytes("smol", 0)
    assert len(srv.device_cache["smol"]) == 0
