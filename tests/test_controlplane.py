"""Predictive prewarm control plane: arrival forecasting, prefix-observer
mining, runtime-learned prefix bakes (reuse hits on observed non-template
prefixes), budgeted eviction under refcount pressure (deferred reclaim
with live borrowers, exact page return, budget never exceeded), predictive
keep-alive, per-function service-class counters, and the ClusterSim trace
JSONL round-trip that lets one trace drive the simulator and the live
gateway replay."""

import os

import jax
import numpy as np
import pytest

from repro.core import api as tidal
from repro.core.scheduler import (ClusterSim, FunctionProfile,
                                  SchedulerConfig, SimRequest, export_trace,
                                  import_trace, make_trace, summarize)
from repro.models.registry import get_smoke_model
from repro.runtime.controlplane import (ControlPlane, EwmaHistogramPredictor,
                                        PrefixObserver, trace_schedule)
from repro.runtime.faas import FaaSRuntime

MAX_LEN = 48
PS = 8
PREFIX_LEN = 2 * PS                       # a 2-page shared prompt root


def _model(n_layers=2):
    return get_smoke_model("smollm-135m", n_layers=n_layers)


def _runtime(model, fn="fn", template_prompt=None, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("page_size", PS)
    kw.setdefault("trace_seq", PREFIX_LEN)
    kw.setdefault("prewarm", False)
    rt = FaaSRuntime(**kw)
    params = model.init_params(jax.random.PRNGKey(0))
    rt.deploy(tidal.static_function(fn, model, params), {},
              template_prompt=template_prompt)
    return rt


def _shared_prefix_prompts(model, n, seed=0, suffix_len=PS):
    """``n`` prompts sharing one 2-page prefix with distinct suffixes."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, model.cfg.vocab_size, PREFIX_LEN)
    return prefix.astype(np.int32), [
        np.concatenate([prefix, rng.integers(0, model.cfg.vocab_size,
                                             suffix_len)]).astype(np.int32)
        for _ in range(n)]


# ---------------------------------------------------------------------------
# arrival forecasting
# ---------------------------------------------------------------------------

def test_predictor_periodic_forecast():
    """A strictly periodic function forecasts: high arrival probability
    once the period is nearly elapsed, none right after an arrival, none
    after going quiet past every observed gap."""
    p = EwmaHistogramPredictor()
    for t in (0.0, 10.0, 20.0, 30.0, 40.0):
        p.observe("f", t)
    assert p.n_observations("f") == 5
    assert p.rate("f", 41.0) == pytest.approx(0.1)
    # just after an arrival: the next one is ~a full period away
    assert p.p_within("f", 41.0, 2.0) == 0.0
    # late in the period (slack-adjusted elapsed 8s): arrival imminent
    assert p.p_within("f", 50.0, 2.5) == 1.0
    eta = p.next_eta("f", 50.0)
    assert eta is not None and 0.0 <= eta <= 2.5
    # quiet past every observed gap: the forecast collapses to idle
    assert p.p_within("f", 200.0, 5.0) == 0.0
    assert p.next_eta("f", 200.0) is None
    assert p.functions() == ["f"]


def test_predictor_unseen_function():
    p = EwmaHistogramPredictor()
    assert p.rate("ghost", 1.0) == 0.0
    assert p.p_within("ghost", 1.0, 10.0) == 0.0
    assert p.next_eta("ghost", 1.0) is None


# ---------------------------------------------------------------------------
# prefix-observer mining
# ---------------------------------------------------------------------------

def test_observer_nominates_deepest_shared_extent():
    """Three prompts sharing 2 pages nominate ONE node — the 2-page
    extent, covering its depth-1 ancestor — once min_hits is reached."""
    m = _model(n_layers=1)
    obs = PrefixObserver(PS, min_hits=3)
    prefix, prompts = _shared_prefix_prompts(m, 3)
    for i, prompt in enumerate(prompts):
        obs.observe(("fn", ()), prompt, now=float(i))
    noms = obs.nominate(now=3.0, limit=8)
    assert len(noms) == 1                  # ancestor covered, suffixes cold
    key, node = noms[0]
    assert key[1] == 2                     # depth: two pages
    np.testing.assert_array_equal(node.tokens, prefix)
    obs.mark_baked(key)
    assert obs.nominate(now=3.0, limit=8) == []
    obs.forget(key)                        # evicted: must re-earn its hits
    assert obs.node_stats(key)[0] == 0
    assert obs.nominate(now=3.0, limit=8) == []


def test_observer_below_min_hits_and_bounded_nodes():
    m = _model(n_layers=1)
    obs = PrefixObserver(PS, min_hits=3, max_nodes=8)
    _, prompts = _shared_prefix_prompts(m, 2)
    for prompt in prompts:
        obs.observe(("fn", ()), prompt, now=0.0)
    assert obs.nominate(now=1.0) == []     # 2 hits < min_hits
    rng = np.random.default_rng(7)
    for i in range(20):                    # many distinct cold prompts
        obs.observe(("fn", ()), rng.integers(
            0, m.cfg.vocab_size, 3 * PS).astype(np.int32), now=float(i))
    assert len(obs) <= 8


# ---------------------------------------------------------------------------
# runtime-learned reuse (acceptance: non-template prefixes hit)
# ---------------------------------------------------------------------------

def test_runtime_learned_prefix_produces_reuse_hits():
    """A repeated prompt root the deploy never declared gets observed,
    baked at runtime, and the NEXT invocation reuses it suffix-only —
    with bit-identical greedy tokens and pinned bytes within budget."""
    m = _model()
    rt = _runtime(m)                       # no template_prompt anywhere
    cp = ControlPlane(rt, min_hits=3, tick_interval_s=0.0)
    _, prompts = _shared_prefix_prompts(m, 4)

    ref = [rt.submit("fn", {}, p, 4) for p in prompts[:3]]
    assert all(r.reused_prefix_len == 0 for r in ref)   # nothing baked yet
    cp.tick()
    assert cp.stats["prefix_bakes"] == 1
    assert 0 < cp.pinned_nbytes() <= cp.pinned_bytes_budget
    assert len(cp.learned_prefixes()) == 1

    hit = rt.submit("fn", {}, prompts[3], 4)
    assert hit.reused_prefix_len == PREFIX_LEN
    # parity: the reused-prefix serve matches the sequential engine
    from repro.runtime.engine import Engine
    want = Engine(m, rt._engines[list(rt._engines)[0]].engine.params(),
                  donate_cache=False).generate(
        prompts[3][None], max_new_tokens=4, cache_len=MAX_LEN).tokens[0]
    np.testing.assert_array_equal(hit.tokens, want)


def test_bake_runtime_prefix_validations():
    m = _model()
    prefix = np.arange(PREFIX_LEN, dtype=np.int32)
    rt = _runtime(m, template_prompt=prefix)
    with pytest.raises(KeyError):
        rt.bake_runtime_prefix("ghost", prefix)
    with pytest.raises(ValueError):        # not page-aligned
        rt.bake_runtime_prefix("fn", np.arange(PS + 1, dtype=np.int32))
    with pytest.raises(ValueError):        # no suffix room within max_len
        rt.bake_runtime_prefix("fn", np.arange(MAX_LEN, dtype=np.int32))
    # the template bake already covers this extent: no duplicate pin
    assert rt.bake_runtime_prefix("fn", prefix) is None


# ---------------------------------------------------------------------------
# eviction under refcount pressure (acceptance)
# ---------------------------------------------------------------------------

def test_eviction_defers_reclaim_until_borrowers_release():
    """Evicting a borrowed learned prefix unregisters it immediately but
    reclaims its pages only when the last borrower releases — then frees
    exactly the pinned pages."""
    m = _model()
    rt = _runtime(m)
    pool = rt._pool_for(rt.instances[0], m)     # arena is lazily built
    base_free = pool.n_free_pages
    _, prompts = _shared_prefix_prompts(m, 1)
    handle = rt.bake_runtime_prefix("fn", prompts[0][:PREFIX_LEN])
    assert pool.prefix_page_refs(handle) == [1, 1]
    assert pool.n_free_pages == base_free - 2

    from repro.runtime.gateway import InvocationRequest
    h = rt.gateway.submit(InvocationRequest("fn", prompts[0],
                                            max_new_tokens=4))
    stream = h.tokens()
    next(stream)              # prefilled mid-decode: the borrow is LIVE
    assert pool.prefix_page_refs(handle) == [2, 2]

    rt.release_runtime_prefix(handle)
    assert not handle.pinned
    # deferred reclaim: the borrower still aliases both pages
    assert pool.prefix_page_refs(handle) == [1, 1]
    # fresh admissions no longer match the evicted prefix
    h2 = rt.gateway.submit(InvocationRequest("fn", prompts[0],
                                             max_new_tokens=4))
    assert pool.prefix_page_refs(handle) == [1, 1]
    assert h.result().reused_prefix_len == PREFIX_LEN
    assert h2.result().reused_prefix_len == 0
    rt.evict()
    # exact page return: every pinned page came back, none leaked
    assert pool.prefix_page_refs(handle) == [0, 0]
    assert pool.n_free_pages == base_free


def test_pinned_budget_never_exceeded_under_churn():
    """With a budget of exactly one 2-page bake, alternating hot roots
    evict each other round after round — pinned bytes never overshoot,
    and all pages return once the learned cache drops."""
    m = _model()
    rt = _runtime(m)
    pool = rt._pool_for(rt.instances[0], m)     # arena is lazily built
    base_free = pool.n_free_pages
    budget = rt.runtime_prefix_nbytes("fn", PREFIX_LEN)
    cp = ControlPlane(rt, pinned_bytes_budget=budget, min_hits=3,
                      tick_interval_s=0.0)
    roots = [_shared_prefix_prompts(m, 3, seed=s)[1] for s in (1, 2)]
    now = 0.0
    for rnd in range(4):
        for prompt in roots[rnd % 2]:
            now += 0.01
            cp.on_completion("fn", {}, prompt, "warm", 0, now)
        cp.tick(now)
        assert 0 < cp.pinned_nbytes() <= budget
        assert len(cp.learned_prefixes()) == 1
    assert cp.stats["prefix_bakes"] == 4
    assert cp.stats["prefix_evictions"] == 3
    rt._drop_runtime_prefixes()
    assert cp.pinned_nbytes() == 0
    rt.evict()
    assert pool.n_free_pages == base_free


def test_never_fitting_prefix_is_not_retried():
    """A nomination that could NEVER fit the budget is marked off instead
    of thrashing the eviction loop every tick."""
    m = _model()
    rt = _runtime(m)
    cp = ControlPlane(rt, pinned_bytes_budget=1, min_hits=3,
                      tick_interval_s=0.0)
    _, prompts = _shared_prefix_prompts(m, 3)
    for i, p in enumerate(prompts):
        cp.on_completion("fn", {}, p, "warm", 0, float(i))
    cp.tick(1.0)
    assert cp.stats["prefix_bakes"] == 0
    assert cp.pinned_nbytes() == 0
    assert cp.observer.nominate(2.0) == []           # marked, not re-tried


# ---------------------------------------------------------------------------
# prewarm + predictive keep-alive
# ---------------------------------------------------------------------------

def test_prewarm_forks_ahead_of_forecast_burst():
    """With a periodic arrival history, the tick right before the next
    forecast arrival pre-forks the engine; ticks far from it do not."""
    m = _model()
    rt = _runtime(m, keep_alive_s=1e9)
    cp = ControlPlane(rt, prewarm_horizon_s=5.0, prewarm_p=0.5,
                      tick_interval_s=0.0)
    for t in (100.0, 110.0, 120.0, 130.0):
        cp.on_arrival("fn", t, {})
    rt.evict()
    cp.tick(now=131.0)                     # next arrival ~9s out: too far
    assert cp.stats["prewarm_forks"] == 0 and not rt.warm_engines()
    cp.tick(now=138.0)                     # forecast inside the horizon
    assert cp.stats["prewarm_forks"] == 1 and rt.warm_engines()
    cp.tick(now=138.5)                     # already warm: no double fork
    assert cp.stats["prewarm_forks"] == 1


def test_predictive_keep_alive_extends_and_releases():
    """Recurring functions get an extended window; functions predicted
    idle release early — but never on a cold-start guess."""
    rt = None                              # keep_alive_s_for needs no rt
    cp = ControlPlane(extend_factor=6.0, extend_p=0.5,
                      release_factor=0.25, release_p=0.05,
                      min_observations=4)
    for t in (0.0, 10.0, 20.0, 30.0, 40.0):
        cp.predictor.observe("hot", t)
    cp.predictor.observe("cold-guess", 0.0)
    # extended: a 2s default window misses the 10s period, but 6x covers it
    assert cp.keep_alive_s_for("hot", 2.0, now=41.0) == pytest.approx(12.0)
    # idle past every observed gap: early release
    assert cp.keep_alive_s_for("hot", 2.0, now=300.0) == pytest.approx(0.5)
    # one observation is no evidence of idleness: keep the default
    assert cp.keep_alive_s_for("cold-guess", 2.0,
                               now=300.0) == pytest.approx(2.0)


def test_runtime_prune_consults_control_plane(monkeypatch):
    """``_prune`` expires engines under the PREDICTIVE window, not the
    static default, once a control plane is attached."""
    m = _model()
    rt = _runtime(m, keep_alive_s=1e9)
    rt.submit("fn", {}, np.arange(PS, dtype=np.int32), 2)
    assert rt.warm_engines()
    cp = ControlPlane(rt)
    monkeypatch.setattr(cp, "keep_alive_s_for",
                        lambda fn, default_s, now=None: 0.0)
    rt._prune(rt._engines[list(rt._engines)[0]].last_used_s + 1.0)
    assert not rt.warm_engines()


# ---------------------------------------------------------------------------
# per-function service-class counters
# ---------------------------------------------------------------------------

def test_fn_stats_counters_and_rates():
    m = _model()
    rt = _runtime(m)
    cp = ControlPlane(rt, tick_interval_s=0.0)
    prompt = np.arange(PS, dtype=np.int32)
    for _ in range(4):
        rt.submit("fn", {}, prompt, 2)
    s = rt.stats()
    fn = s["functions"]["fn"]
    assert fn["cold"] == 1 and fn["warm"] == 3 and fn["done"] == 4
    assert fn["admitted"] == 4
    assert fn["warm_rate"] == pytest.approx(0.75)
    assert fn["cold_start_rate"] == pytest.approx(0.25)
    assert "engine_failures" in s["gateway"]
    assert s["control_plane"]["observations"] == 4


# ---------------------------------------------------------------------------
# trace export/import: one trace, two consumers
# ---------------------------------------------------------------------------

def test_trace_jsonl_roundtrip_bit_identical(tmp_path):
    trace = make_trace({"mail-fn": 2.0, "code-fn": 1.0}, 5.0,
                       {"mail-fn": "mail", "code-fn": "code"}, seed=3,
                       fn_deadlines={"mail-fn": 0.25},
                       fn_priorities={"code-fn": 2})
    path = tmp_path / "trace.jsonl"
    assert export_trace(trace, os.fspath(path)) == len(trace)
    back = import_trace(os.fspath(path))
    assert back == trace                   # frozen dataclasses: exact floats
    path2 = tmp_path / "again.jsonl"
    export_trace(back, os.fspath(path2))
    assert path.read_bytes() == path2.read_bytes()


def test_imported_trace_drives_sim_identically(tmp_path):
    from repro.core.plans import plan_for
    trace = make_trace({"fn": 3.0}, 4.0, {"fn": "conv"}, seed=1,
                       fn_deadlines={"fn": 1.0})
    path = tmp_path / "t.jsonl"
    export_trace(trace, os.fspath(path))
    prof = {"fn": FunctionProfile(
        "fn", lambda L: plan_for("llama3-8b", 1, L),
        model_bytes=plan_for("llama3-8b", 1, 128).total_weight_bytes)}
    cfg = SchedulerConfig(n_gpus=2, keep_alive_s=5.0)
    a = summarize(ClusterSim(cfg, prof).run(trace))
    b = summarize(ClusterSim(cfg, prof).run(import_trace(os.fspath(path))))
    assert a == b


def test_trace_schedule_carries_deadlines_and_priorities():
    trace = [SimRequest("fn", 0.5, 16, 0, deadline_s=0.2, priority=3)]
    sched = trace_schedule(trace, lambda r: np.arange(r.input_len,
                                                      dtype=np.int32),
                           max_new_tokens=2)
    (due, req), = sched
    assert due == 0.5
    assert req.fn_name == "fn" and req.deadline_s == 0.2
    assert req.priority == 3 and req.max_new_tokens == 2
    assert len(np.asarray(req.prompt)) == 16
